//! Chaos property suite: the merge collectives under injected transport
//! faults are **bit-identical or loudly failed** — never silently wrong.
//!
//! Two layers:
//!
//! * **Per-class deterministic scenarios** — for each fault class (drop,
//!   duplicate, reorder, delay, peer-death) one hand-built schedule that
//!   the staleness rules / tag matching / task-order fold *provably
//!   absorb* (the collective completes with the serial fold's exact
//!   bits), plus — where the class can starve a rank — a schedule that
//!   must fail loudly instead. These run on the channel backend, where
//!   delivery is synchronous and the outcome is exactly reproducible.
//! * **A seeded sweep** — [`seeded_schedule`] generates random fault
//!   schedules; every rank that returns `Ok` must hold the serial fold's
//!   bits. `CHICLE_CHAOS_SEEDS=n` widens the sweep (the nightly CI job
//!   uses 32), `CHICLE_CHAOS_SEED=s` replays one seed; failing seeds are
//!   written to `results/chaos_failures.txt` so CI can upload them as an
//!   artifact for replay.
//!
//! The emission geometry the hand-built schedules rely on (one part per
//! rank, ring): edge `r → right(r)` carries the scatter `UpdateSlice` as
//! emission 0 and then `k−1` all-gather `Segment`s; every other edge
//! `r → s` carries exactly one `UpdateSlice`.

mod transport_conformance;

use std::sync::Arc;
use std::time::Duration;

use chicle::algos::{Algorithm, Backend, CocoaAlgo, LocalUpdate, ModelVec};
use chicle::config::CocoaConfig;
use chicle::transport::{
    fetch_state, ring_allreduce, seeded_schedule, tree_allreduce, AllreduceKind, AllreduceRun,
    CollectiveCtx, Fault, FaultPlan, FaultTransport, GroupHandle, Payload, Transport,
    TransportError, UpdatePart,
};
use chicle::util::Rng;

use transport_conformance as conf;

/// Doomed waits fail in milliseconds, not the collectives' 10 s backstop.
const RECV_CAP: Duration = Duration::from_millis(150);

fn cocoa(len: usize) -> Arc<dyn Algorithm> {
    Arc::new(CocoaAlgo::new(CocoaConfig::default(), Backend::native_cocoa(), 100, len))
}

/// Non-contiguous node ids (rank ≠ id), shared by schedules and runner.
fn chaos_order(k: usize) -> Vec<u32> {
    (0..k as u32).map(|i| 7 * i + 2).collect()
}

/// Run one collective with a [`FaultPlan`] per rank. Threads hand their
/// wrapped endpoints back (nothing is dropped mid-scope), so held
/// messages stay held and a "dead" rank's endpoint survives for the
/// rejoin scenarios. Results are returned, not unwrapped — failing
/// loudly is an acceptable chaos outcome.
#[allow(clippy::type_complexity)]
fn run_faulted(
    make: conf::GroupCtor,
    algo: &Arc<dyn Algorithm>,
    model: &ModelVec,
    updates: &[LocalUpdate],
    kind: AllreduceKind,
    plans: &[FaultPlan],
) -> (GroupHandle, Vec<(Result<AllreduceRun, TransportError>, FaultTransport)>) {
    let k = updates.len();
    assert_eq!(plans.len(), k, "one plan per rank");
    let order = chaos_order(k);
    let group = make();
    let endpoints: Vec<FaultTransport> = order
        .iter()
        .zip(plans)
        .map(|(&n, plan)| FaultTransport::new(group.join(n), plan.clone()))
        .collect();
    let epoch = group.membership().epoch;
    let outs = std::thread::scope(|s| {
        let handles: Vec<_> = endpoints
            .into_iter()
            .enumerate()
            .map(|(rank, mut ep)| {
                let order = &order;
                let algo = Arc::clone(algo);
                s.spawn(move || {
                    let parts = vec![(rank, updates[rank].clone())];
                    let ctx = CollectiveCtx {
                        algo: algo.as_ref(),
                        model,
                        parts: &parts,
                        k_tasks: updates.len(),
                        order,
                        epoch,
                        iter: 42,
                    };
                    let result = match kind {
                        AllreduceKind::Ring => ring_allreduce(&mut ep, &ctx),
                        AllreduceKind::Tree => tree_allreduce(&mut ep, &ctx),
                    };
                    (result, ep)
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    });
    (group, outs)
}

/// The chaos invariant, checked rank by rank: `Ok` means the serial
/// fold's exact bits; `Err` is a loud failure and always acceptable.
fn assert_bits_or_loud(
    tag: &str,
    serial: &ModelVec,
    outs: &[(Result<AllreduceRun, TransportError>, FaultTransport)],
) {
    for (rank, (result, _)) in outs.iter().enumerate() {
        if let Ok(run) = result {
            assert_eq!(&run.model, serial, "{tag}: rank {rank} silently corrupted the merge");
        }
    }
}

/// Shorthand for the absorbed scenarios: every rank must complete *and*
/// match the serial fold — the schedule is supposed to be invisible.
fn assert_absorbed(
    tag: &str,
    serial: &ModelVec,
    outs: &[(Result<AllreduceRun, TransportError>, FaultTransport)],
) {
    assert_bits_or_loud(tag, serial, outs);
    for (rank, (result, _)) in outs.iter().enumerate() {
        assert!(
            result.is_ok(),
            "{tag}: rank {rank} failed a schedule the rules should absorb: {:?}",
            result.as_ref().err()
        );
    }
}

fn serial_fold(algo: &Arc<dyn Algorithm>, model: &ModelVec, updates: &[LocalUpdate]) -> ModelVec {
    let mut out = model.clone();
    algo.merge(&mut out, updates, updates.len());
    out
}

/// **Drop, absorbed**: `Duplicate{nth: i}` + `Drop{nth: i+1}` kills
/// exactly the redundant copy — the wire carries precisely the original
/// traffic, so the collective cannot tell the schedule from a clean run.
#[test]
fn absorbed_drop_of_a_duplicated_emission_changes_nothing() {
    let algo = cocoa(50);
    let model = vec![0.75f32; 50];
    let mut rng = Rng::seed_from_u64(101);
    let updates = conf::random_updates(&mut rng, 2, 50);
    let serial = serial_fold(&algo, &model, &updates);

    let order = chaos_order(2);
    let mut plans = vec![FaultPlan::clean().with_recv_cap(RECV_CAP); 2];
    plans[0].faults = vec![
        Fault::Duplicate { to: order[1], nth: 0 },
        Fault::Drop { to: order[1], nth: 1 },
    ];
    let (_g, outs) = run_faulted(
        GroupHandle::channel,
        &algo,
        &model,
        &updates,
        AllreduceKind::Ring,
        &plans,
    );
    assert_absorbed("dup+drop", &serial, &outs);
}

/// **Drop, loud**: swallowing an essential all-gather segment starves
/// its receiver into a timeout. The starved rank fails loudly; any rank
/// that does complete still holds the serial bits (rank 0 here — its
/// inbound traffic is untouched).
#[test]
fn loud_drop_of_an_essential_segment_times_out_not_corrupts() {
    let algo = cocoa(50);
    let model = vec![-1.25f32; 50];
    let mut rng = Rng::seed_from_u64(103);
    let updates = conf::random_updates(&mut rng, 2, 50);
    let serial = serial_fold(&algo, &model, &updates);

    let order = chaos_order(2);
    let mut plans = vec![FaultPlan::clean().with_recv_cap(RECV_CAP); 2];
    // Edge 0→1, emission 1 = rank 0's only all-gather Segment.
    plans[0].faults = vec![Fault::Drop { to: order[1], nth: 1 }];
    let (_g, outs) = run_faulted(
        GroupHandle::channel,
        &algo,
        &model,
        &updates,
        AllreduceKind::Ring,
        &plans,
    );
    assert_bits_or_loud("loud drop", &serial, &outs);
    assert!(
        matches!(outs[1].0, Err(TransportError::Timeout)),
        "the starved rank must time out loudly, got {:?}",
        outs[1].0.as_ref().map(|_| "Ok")
    );
    let ok = outs[0].0.as_ref().expect("rank 0's inbound traffic is untouched");
    assert_eq!(ok.model, serial, "the completing rank must still hold the serial bits");
}

/// **Duplicate, absorbed**: a duplicated scatter slice arrives after the
/// owner already collected its `k_tasks` parts; `recv_matching` stashes
/// the straggler (it never matches a later step's tag) and it dies in
/// the stash — the fold is keyed by tag and task order, not arrival
/// count.
#[test]
fn absorbed_duplicate_slice_is_stashed_not_double_folded() {
    let algo = cocoa(64);
    let model = vec![2.0f32; 64];
    let mut rng = Rng::seed_from_u64(107);
    let updates = conf::random_updates(&mut rng, 2, 64);
    let serial = serial_fold(&algo, &model, &updates);

    let order = chaos_order(2);
    let mut plans = vec![FaultPlan::clean().with_recv_cap(RECV_CAP); 2];
    plans[0].faults = vec![Fault::Duplicate { to: order[1], nth: 0 }];
    let (_g, outs) = run_faulted(
        GroupHandle::channel,
        &algo,
        &model,
        &updates,
        AllreduceKind::Ring,
        &plans,
    );
    assert_absorbed("duplicate", &serial, &outs);
}

/// **Reorder, absorbed**: swapping rank 1's scatter slice behind its
/// first all-gather segment on the edge to rank 2 delivers `Segment`
/// before the `UpdateSlice` rank 2 is still collecting — the stash
/// absorbs the early segment and replays it when the all-gather asks.
#[test]
fn absorbed_reorder_is_replayed_from_the_stash() {
    let algo = cocoa(97);
    let model: ModelVec = (0..97).map(|i| i as f32 * 0.5).collect();
    let mut rng = Rng::seed_from_u64(109);
    let updates = conf::random_updates(&mut rng, 4, 97);
    let serial = serial_fold(&algo, &model, &updates);

    let order = chaos_order(4);
    let mut plans = vec![FaultPlan::clean().with_recv_cap(RECV_CAP); 4];
    // Edge 1→2 (= right of 1): hold the UpdateSlice (emission 0) until
    // the Segment behind it (emission 1) hits the wire.
    plans[1].faults = vec![Fault::Reorder { to: order[2], nth: 0 }];
    let (_g, outs) = run_faulted(
        GroupHandle::channel,
        &algo,
        &model,
        &updates,
        AllreduceKind::Ring,
        &plans,
    );
    assert_absorbed("reorder", &serial, &outs);
}

/// **Delay, absorbed**: a scatter slice held for a few of the sender's
/// own operation ticks releases while its receiver is still blocked
/// collecting — late, but matched by tag exactly like an on-time
/// arrival.
#[test]
fn absorbed_delay_arrives_late_but_exact() {
    let algo = cocoa(81);
    let model = vec![0.125f32; 81];
    let mut rng = Rng::seed_from_u64(113);
    let updates = conf::random_updates(&mut rng, 3, 81);
    let serial = serial_fold(&algo, &model, &updates);

    let order = chaos_order(3);
    let mut plans = vec![FaultPlan::clean().with_recv_cap(RECV_CAP); 3];
    plans[0].faults = vec![Fault::Delay { to: order[1], nth: 0, ops: 3 }];
    let (_g, outs) = run_faulted(
        GroupHandle::channel,
        &algo,
        &model,
        &updates,
        AllreduceKind::Ring,
        &plans,
    );
    assert_absorbed("delay", &serial, &outs);
}

/// **Peer-death**: phase A — a rank killed after its scatter sends
/// starves the all-gather; every survivor fails loudly and nobody holds
/// wrong bits. Phase B — the survivors regroup (new epoch, new order),
/// the dead regime's straggling slice is sieved by the staleness rule,
/// a rejoiner is served state from a peer mid-entry, and the survivor
/// collective is bit-identical to its serial fold: the full
/// revoke/rejoin story under churn.
#[test]
fn peer_death_fails_loud_then_the_next_regime_absorbs_the_stragglers() {
    let algo = cocoa(60);
    let model: ModelVec = (0..60).map(|i| i as f32 * 0.25 - 3.0).collect();
    let mut rng = Rng::seed_from_u64(13);
    let updates = conf::random_updates(&mut rng, 3, 60);
    let serial = serial_fold(&algo, &model, &updates);

    let order = chaos_order(3);
    let mut plans = vec![FaultPlan::clean().with_recv_cap(RECV_CAP); 3];
    // Rank 2 dies right after its two scatter slices — before any
    // all-gather traffic, so both survivors starve deterministically.
    plans[2].faults = vec![Fault::KillAfterSends { after: 2 }];
    let (group, outs) = run_faulted(
        GroupHandle::channel,
        &algo,
        &model,
        &updates,
        AllreduceKind::Ring,
        &plans,
    );

    // Phase A: loud everywhere, wrong nowhere.
    assert_bits_or_loud("peer death", &serial, &outs);
    let (results, mut endpoints): (Vec<_>, Vec<_>) = outs.into_iter().unzip();
    assert!(
        matches!(results[2], Err(TransportError::Closed(_))),
        "the killed rank must observe its own death, got {results:?}"
    );
    assert!(
        results[..2].iter().all(|r| r.is_err()),
        "losing a peer before the all-gather must starve both survivors: {results:?}"
    );

    // Phase B: the dead rank's endpoint outlives its wrapper just long
    // enough to model a straggler from the dead regime — a slice shaped
    // exactly like rank 1's contribution to the *next* collective, the
    // payload that would corrupt the merge if the sieve let it through.
    let mut straggler = endpoints.remove(2).into_inner();
    straggler
        .send(
            order[0],
            Payload::UpdateSlice {
                iter: 43,
                seg: 0,
                part: UpdatePart { task_idx: 1, samples: 5, delta: vec![2.5; 30] },
            },
        )
        .unwrap();
    drop(straggler); // leave: the epoch moves past the straggler's stamp

    let mut rejoiner = group.join(99);
    rejoiner.send(order[0], Payload::StateRequest).unwrap();

    let survivors: Vec<Box<dyn Transport>> =
        endpoints.into_iter().map(|ep| ep.into_inner()).collect();
    let updates2 = conf::random_updates(&mut rng, 2, 60);
    let serial2 = serial_fold(&algo, &model, &updates2);
    let new_order = [order[0], order[1]];
    let epoch = group.membership().epoch;
    let (runs, _live_eps): (Vec<AllreduceRun>, Vec<_>) = std::thread::scope(|s| {
        let handles: Vec<_> = survivors
            .into_iter()
            .enumerate()
            .map(|(rank, mut ep)| {
                let (algo, model, updates2, new_order) = (&algo, &model, &updates2, &new_order);
                s.spawn(move || {
                    let parts = vec![(rank, updates2[rank].clone())];
                    let ctx = CollectiveCtx {
                        algo: algo.as_ref(),
                        model,
                        parts: &parts,
                        k_tasks: 2,
                        order: new_order,
                        epoch,
                        iter: 43,
                    };
                    let run = ring_allreduce(ep.as_mut(), &ctx).unwrap();
                    (run, ep)
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).unzip()
    });
    for (rank, run) in runs.iter().enumerate() {
        assert_eq!(run.model, serial2, "post-churn rank {rank} diverged from the serial fold");
    }
    assert!(
        runs[0].stats.stale_dropped >= 1,
        "the dead regime's straggler must be sieved, not folded"
    );
    assert_eq!(runs[0].stats.state_served, 1, "the rejoin request must be served at entry");
    let state = fetch_state(rejoiner.as_mut(), order[0], Duration::from_secs(1))
        .expect("the rejoin reply was queued before the collective");
    assert_eq!(state, model, "rejoin state must be the pre-merge model");
}

/// One seed's sweep: wrap every rank in its seeded plan and check the
/// chaos invariant. Returns a replayable description on violation.
fn sweep_one(
    make: conf::GroupCtor,
    seed: u64,
    kind: AllreduceKind,
    algo: &Arc<dyn Algorithm>,
    model: &ModelVec,
    updates: &[LocalUpdate],
    serial: &ModelVec,
) -> Result<(), String> {
    let order = chaos_order(updates.len());
    let plans: Vec<FaultPlan> = seeded_schedule(seed, &order)
        .into_iter()
        .map(|p| p.with_recv_cap(RECV_CAP))
        .collect();
    let (_g, outs) = run_faulted(make, algo, model, updates, kind, &plans);
    for (rank, (result, _)) in outs.iter().enumerate() {
        if let Ok(run) = result {
            if run.model != *serial {
                return Err(format!(
                    "seed={seed} kind={kind:?} rank={rank} faults={:?}: silent corruption",
                    plans[rank].faults
                ));
            }
        }
    }
    Ok(())
}

fn sweep_seeds() -> Vec<u64> {
    if let Ok(s) = std::env::var("CHICLE_CHAOS_SEED") {
        return vec![s.parse().expect("CHICLE_CHAOS_SEED must be a u64")];
    }
    let n: u64 = std::env::var("CHICLE_CHAOS_SEEDS")
        .map(|v| v.parse().expect("CHICLE_CHAOS_SEEDS must be a u64 count"))
        .unwrap_or(6);
    (0..n).collect()
}

/// The seeded sweep over the channel backend: random schedules, both
/// collectives, k = 4. Any rank that claims success with non-serial bits
/// fails the run; the offending seeds land in
/// `results/chaos_failures.txt` for CI to upload and a developer to
/// replay with `CHICLE_CHAOS_SEED=<seed>`.
#[test]
fn seeded_sweep_finds_no_silent_corruption() {
    let algo = cocoa(97);
    let model: ModelVec = (0..97).map(|i| (i as f32).sin()).collect();
    let mut rng = Rng::seed_from_u64(127);
    let updates = conf::random_updates(&mut rng, 4, 97);
    let serial = serial_fold(&algo, &model, &updates);

    let mut failures = Vec::new();
    for &seed in &sweep_seeds() {
        for kind in [AllreduceKind::Ring, AllreduceKind::Tree] {
            if let Err(desc) =
                sweep_one(GroupHandle::channel, seed, kind, &algo, &model, &updates, &serial)
            {
                failures.push(desc);
            }
        }
    }
    if !failures.is_empty() {
        std::fs::create_dir_all("results").ok();
        std::fs::write("results/chaos_failures.txt", failures.join("\n") + "\n").ok();
        panic!(
            "chaos sweep found silent corruption (replay with CHICLE_CHAOS_SEED=<seed>):\n{}",
            failures.join("\n")
        );
    }
}

/// Chaos over real sockets: a handful of seeded schedules plus the
/// absorbed-duplicate scenario on the TCP backend. Socket timing makes
/// loud-vs-absorbed outcomes nondeterministic, so only the invariant
/// that never depends on timing is asserted: bits-or-loud.
#[test]
fn tcp_chaos_smoke_bits_or_loud() {
    let algo = cocoa(64);
    let model = vec![1.5f32; 64];
    let mut rng = Rng::seed_from_u64(131);
    let updates = conf::random_updates(&mut rng, 3, 64);
    let serial = serial_fold(&algo, &model, &updates);

    for seed in [0u64, 1, 2] {
        for kind in [AllreduceKind::Ring, AllreduceKind::Tree] {
            if let Err(desc) =
                sweep_one(GroupHandle::tcp, seed, kind, &algo, &model, &updates, &serial)
            {
                panic!("tcp chaos smoke: {desc}");
            }
        }
    }

    // The duplicate-absorption argument (stash + tag matching) does not
    // depend on delivery timing, so it must hold over TCP too.
    let updates = conf::random_updates(&mut rng, 2, 64);
    let serial = serial_fold(&algo, &model, &updates);
    let order = chaos_order(2);
    let mut plans = vec![FaultPlan::clean().with_recv_cap(Duration::from_secs(2)); 2];
    plans[0].faults = vec![Fault::Duplicate { to: order[1], nth: 0 }];
    let (_g, outs) =
        run_faulted(GroupHandle::tcp, &algo, &model, &updates, AllreduceKind::Ring, &plans);
    assert_absorbed("tcp duplicate", &serial, &outs);
}
