//! Integration: full training sessions across the evaluation matrix reach
//! their convergence targets (native backend; the HLO path is covered by
//! hlo_native_equivalence.rs plus the quickstart example).

use chicle::config::{
    AlgoConfig, ElasticSpec, ModelKind, Partitioning, SessionConfig, TaskModel,
};
use chicle::coordinator::TrainingSession;
use chicle::data::synth;
use chicle::metrics::Metric;

fn cocoa_cfg(name: &str, nodes: usize) -> SessionConfig {
    let mut cfg = SessionConfig::cocoa(name, nodes);
    cfg.chunk_bytes = 8 * 1024;
    cfg.max_iters = 80;
    cfg
}

#[test]
fn cocoa_higgs_rigid_reaches_target_gap() {
    let ds = synth::higgs_like(4000, 1);
    let mut s = TrainingSession::new(cocoa_cfg("it-rigid", 8), ds).unwrap();
    let log = s.run().unwrap();
    assert!(log.last_gap().unwrap() < 1e-3, "gap {:?}", log.last_gap());
}

#[test]
fn cocoa_criteo_sparse_reaches_target_gap() {
    let ds = synth::criteo_like_with(4000, 20_000, 20, 16, 2);
    let mut cfg = cocoa_cfg("it-sparse", 8);
    cfg.max_iters = 120;
    let mut s = TrainingSession::new(cfg, ds).unwrap();
    let log = s.run().unwrap();
    assert!(log.last_gap().unwrap() < 1e-2, "gap {:?}", log.last_gap());
}

#[test]
fn cocoa_elastic_scale_in_still_converges() {
    let ds = synth::higgs_like(4000, 3);
    let mut cfg = cocoa_cfg("it-elastic", 16);
    cfg.elastic = ElasticSpec::Gradual { from: 16, to: 2, interval_s: 8.0 };
    let mut s = TrainingSession::new(cfg, ds).unwrap();
    let log = s.run().unwrap();
    assert!(log.last_gap().unwrap() < 1e-3, "gap {:?}", log.last_gap());
    // Scale-in happened during the run.
    assert!(log.records.iter().any(|r| r.n_tasks == 16));
    assert!(log.records.last().unwrap().n_tasks < 16);
}

#[test]
fn cocoa_heterogeneous_with_rebalance_converges() {
    let ds = synth::higgs_like(4000, 4);
    let mut cfg = cocoa_cfg("it-hetero", 8);
    cfg.elastic = ElasticSpec::Heterogeneous { fast: 4, slow: 4, factor: 1.5 };
    cfg.policies.rebalance = true;
    let mut s = TrainingSession::new(cfg, ds).unwrap();
    let log = s.run().unwrap();
    assert!(log.last_gap().unwrap() < 1e-3, "gap {:?}", log.last_gap());
}

#[test]
fn microtask_emulation_tracks_k_not_nodes() {
    // K=32 micro-tasks on an 8-node rigid cluster: per-epoch convergence
    // must match K=32 on 16 nodes; projected time must not.
    let run = |nodes: usize| {
        let ds = synth::higgs_like(3000, 5);
        let mut cfg = cocoa_cfg("it-micro", nodes).with_microtasks(32);
        cfg.max_iters = 10;
        let mut s = TrainingSession::new(cfg, ds).unwrap();
        s.run_iters(10).unwrap()
    };
    let a = run(8);
    let b = run(16);
    for (ra, rb) in a.records.iter().zip(&b.records) {
        assert_eq!(ra.metric.unwrap().value(), rb.metric.unwrap().value());
    }
    assert!(a.total_vtime() > b.total_vtime(), "8 nodes must be slower");
}

#[test]
fn contiguous_partitioning_hurts_sessioned_data() {
    // The Fig 8 mechanism as a test: on session-correlated sparse data,
    // contiguous (Snap-ML-style) partitioning converges slower per epoch
    // than random chunk assignment.
    let run = |partitioning: Partitioning| {
        let ds = synth::criteo_like_with(6000, 20_000, 20, 16, 6);
        let mut cfg = cocoa_cfg("it-part", 16);
        cfg.partitioning = partitioning;
        cfg.max_iters = 8;
        let mut s = TrainingSession::new(cfg, ds).unwrap();
        let log = s.run_iters(8).unwrap();
        log.last_gap().unwrap()
    };
    let random = run(Partitioning::RandomChunks);
    let contiguous = run(Partitioning::Contiguous);
    assert!(
        random < contiguous,
        "random {random} should beat contiguous {contiguous}"
    );
}

#[test]
fn lsgd_mlp_reaches_target_accuracy() {
    let ds = synth::fmnist_like(2500, 7);
    let mut cfg = SessionConfig::lsgd("it-mlp", ModelKind::Mlp, 4);
    cfg.chunk_bytes = 48 * 1024;
    cfg.max_iters = 150;
    if let AlgoConfig::Lsgd(l) = &mut cfg.algo {
        l.lr = 4e-3;
        l.eval_every = 10;
        l.target_acc = 0.75;
    }
    let mut s = TrainingSession::new(cfg, ds).unwrap();
    let log = s.run().unwrap();
    assert!(
        log.last_accuracy().unwrap() >= 0.75,
        "acc {:?}",
        log.last_accuracy()
    );
}

#[test]
fn lsgd_uni_tasks_track_node_count() {
    let ds = synth::fmnist_like(2500, 8);
    let mut cfg = SessionConfig::lsgd("it-elastic-mlp", ModelKind::Mlp, 2);
    cfg.chunk_bytes = 32 * 1024;
    cfg.elastic = ElasticSpec::Gradual { from: 2, to: 6, interval_s: 5.0 };
    cfg.max_iters = 40;
    if let AlgoConfig::Lsgd(l) = &mut cfg.algo {
        l.lr = 4e-3;
        l.eval_every = 40; // focus on mechanics, not metric
        l.target_acc = 2.0;
    }
    let mut s = TrainingSession::new(cfg, ds).unwrap();
    let log = s.run_iters(40).unwrap();
    // Global batch K·L·H grows with the node count.
    let first = &log.records[0];
    let last = log.records.last().unwrap();
    assert_eq!(first.n_tasks, 2);
    assert_eq!(last.n_tasks, 6);
    assert_eq!(first.samples, 2 * 8 * 16);
    assert_eq!(last.samples, 6 * 8 * 16);
}

#[test]
fn lsgd_msgd_mode_matches_h1() {
    // H=1 must process exactly K·L samples per iteration (mSGD).
    let ds = synth::fmnist_like(1500, 9);
    let mut cfg = SessionConfig::lsgd("it-msgd", ModelKind::Mlp, 4);
    cfg.chunk_bytes = 32 * 1024;
    if let AlgoConfig::Lsgd(l) = &mut cfg.algo {
        l.h = 1;
        l.eval_every = 100;
        l.target_acc = 2.0;
    }
    cfg.max_iters = 3;
    let mut s = TrainingSession::new(cfg, ds).unwrap();
    let log = s.run_iters(3).unwrap();
    assert_eq!(log.records[0].samples, 4 * 8);
}

#[test]
fn straggler_policy_mitigates_acute_slowdown() {
    // A 4-node cluster where one node is 4x slow: with the straggler
    // policy the slow node shed chunks within a few iterations.
    let ds = synth::higgs_like(3000, 10);
    let mut cfg = cocoa_cfg("it-straggler", 4);
    cfg.elastic = ElasticSpec::Trace { points: vec![(0.0, vec![1.0, 1.0, 1.0, 0.25])] };
    cfg.policies.rebalance = false;
    cfg.policies.straggler = true;
    cfg.policies.straggler_factor = 1.5;
    cfg.max_iters = 12;
    let mut s = TrainingSession::new(cfg, ds).unwrap();
    s.run_iters(12).unwrap();
    let samples: Vec<usize> = s.trainer().tasks().iter().map(|t| t.n_samples()).collect();
    let slow = samples[3];
    let fast_avg = (samples[0] + samples[1] + samples[2]) / 3;
    assert!(slow < fast_avg, "straggler should shed load: {samples:?}");
}

#[test]
fn shuffle_policy_preserves_convergence() {
    let ds = synth::higgs_like(3000, 11);
    let mut cfg = cocoa_cfg("it-shuffle", 4);
    cfg.policies.shuffle = true;
    cfg.policies.shuffle_every = 2;
    let mut s = TrainingSession::new(cfg, ds).unwrap();
    let log = s.run().unwrap();
    assert!(log.last_gap().unwrap() < 1e-3);
}

#[test]
fn metric_series_records_epochs_and_time() {
    let ds = synth::higgs_like(2000, 12);
    let mut s = TrainingSession::new(cocoa_cfg("it-metrics", 4), ds).unwrap();
    let log = s.run_iters(5).unwrap();
    assert_eq!(log.records.len(), 5);
    // CoCoA: one epoch per iteration.
    assert!((log.records[4].epochs - 5.0).abs() < 1e-9);
    assert!(log.records.iter().all(|r| matches!(
        r.metric,
        Some(Metric::DualityGap(_))
    )));
    let tsv = log.to_tsv();
    assert_eq!(tsv.lines().count(), 6);
}
