//! Heterogeneous-cluster demo: 3 fast + 1 half-speed node. The rebalance
//! policy learns per-sample runtimes from iteration timings and drains
//! chunks from the slow node until all tasks finish together (paper §4.5).
//!
//!     cargo run --release --example heterogeneous_cluster

use chicle::config::{ElasticSpec, SessionConfig};
use chicle::coordinator::TrainingSession;
use chicle::data::synth;

fn main() -> chicle::Result<()> {
    let dataset = synth::higgs_like(12_000, 3);
    let mut cfg = SessionConfig::cocoa("hetero-demo", 4);
    cfg.chunk_bytes = 8 * 1024;
    cfg.elastic = ElasticSpec::Trace { points: vec![(0.0, vec![1.0, 1.0, 1.0, 0.5])] };
    cfg.policies.rebalance = true;
    cfg.policies.rebalance_step = 2;
    cfg.max_iters = 15;

    let mut session = TrainingSession::new(cfg, dataset)?;
    session.run_iters(15)?;

    println!("-- task runtime swimlanes (node 3 runs at half speed) --");
    print!("{}", session.swimlanes().render_ascii(90));
    println!("\n-- final relative workload --");
    print!("{}", session.swimlanes().render_workload());

    println!("\niteration durations (time units):");
    for it in 0..session.swimlanes().n_iterations() {
        if let Some(d) = session.swimlanes().iteration_duration(it) {
            let imb = session.swimlanes().imbalance(it).unwrap_or(1.0);
            println!("  iter {it:>2}: {:.3} (imbalance {imb:.2}x)", d.as_secs_f64());
        }
    }
    let first = session.swimlanes().imbalance(0).unwrap();
    let last_iter = session.swimlanes().n_iterations() - 1;
    let last = session.swimlanes().imbalance(last_iter).unwrap();
    println!("\nimbalance: {first:.2}x -> {last:.2}x (rebalancer learned node speeds)");
    Ok(())
}
