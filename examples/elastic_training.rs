//! Elastic training demo: scale out from 2 to 8 nodes *while training*,
//! watching the data parallelism K track the node count and the chunks
//! redistribute — the uni-tasks core idea (paper §3).
//!
//!     cargo run --release --example elastic_training

use chicle::config::{ElasticSpec, SessionConfig};
use chicle::coordinator::TrainingSession;
use chicle::data::synth;

fn main() -> chicle::Result<()> {
    let dataset = synth::higgs_like(16_000, 7);
    let mut cfg = SessionConfig::cocoa("elastic-demo", 2);
    cfg.chunk_bytes = 16 * 1024;
    // +2 nodes every 10 virtual seconds, 2 → 8.
    cfg.elastic = ElasticSpec::Gradual { from: 2, to: 8, interval_s: 10.0 };
    cfg.max_iters = 25;

    let mut session = TrainingSession::new(cfg, dataset)?;
    let log = session.run()?;

    println!("iter  vtime   nodes(K)  epochs  duality gap");
    for r in &log.records {
        println!(
            "{:>4}  {:>6.1}  {:>8}  {:>6.1}  {}",
            r.iter,
            r.vtime.as_secs_f64(),
            r.n_tasks,
            r.epochs,
            r.metric.map_or("—".into(), |m| format!("{:.6}", m.value())),
        );
    }
    let first_k = log.records.first().unwrap().n_tasks;
    let last_k = log.records.last().unwrap().n_tasks;
    println!("\nscaled from K={first_k} to K={last_k} while converging to gap {:?}", log.last_gap());
    assert_eq!(first_k, 2);
    assert_eq!(last_k, 8);
    Ok(())
}
