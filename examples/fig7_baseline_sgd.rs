//! Figure 7: baseline comparison for mSGD — "Chicle vs PyTorch"
//! (paper §5.2 / §A.1).
//!
//! The paper's point: Chicle's elasticity machinery costs nothing in the
//! rigid case — per-epoch convergence is identical to the rigid framework
//! and per-iteration overhead is negligible. PyTorch itself is not
//! available offline, so the rigid baseline is this stack with every
//! Chicle policy disabled and a fixed K=16 (same compute path → isolates
//! the framework delta exactly; DESIGN.md §Substitutions). We report:
//!
//! * per-epoch convergence of both (must coincide),
//! * measured wall-clock per iteration (the chicle machinery's overhead),
//! * final/best test accuracy (paper: 65.2% CIFAR-10, 91.4% F-MNIST).

use chicle::config::{AlgoConfig, TimeModel};
use chicle::coordinator::TrainingSession;
use chicle::harness::{fast_mode, print_table, rigid_policies, summarize, write_tsv, Workload};

fn main() -> chicle::Result<()> {
    let workloads = [Workload::FmnistLike, Workload::CifarLike];
    let mut rows = Vec::new();
    for w in &workloads {
        for (label, chicle_mode) in [("rigid-baseline", false), ("chicle", true)] {
            let name = format!("fig7_{}_{}", w.name(), label);
            let ds = w.dataset(42);
            let mut cfg = w.session(&name, 16);
            // mSGD: the paper compares against PyTorch with H=1, lr 2e-3,
            // momentum 0.9.
            if let AlgoConfig::Lsgd(l) = &mut cfg.algo {
                l.h = 1;
                l.lr = 2e-3;
                l.scale_lr = false;
                l.eval_every = 20;
                l.target_acc = 2.0; // run the full horizon
            }
            cfg.time_model = TimeModel::Measured;
            cfg.max_iters = if fast_mode() { 60 } else { 1500 };
            cfg.max_epochs = if fast_mode() { 4.0 } else { 12.0 };
            if !chicle_mode {
                cfg.policies = rigid_policies();
            }
            let mut s = TrainingSession::new(cfg, ds)?;
            let log = s.run()?;
            write_tsv(&format!("{name}.tsv"), &log.to_tsv())?;
            let best = log.best_accuracy().unwrap_or(0.0);
            let per_iter_ms =
                log.total_wall().as_secs_f64() * 1000.0 / log.records.len().max(1) as f64;
            let (epochs, _, _) = summarize(&log, w.target());
            rows.push(vec![
                w.name().to_string(),
                label.to_string(),
                format!("{best:.3}"),
                epochs,
                format!("{per_iter_ms:.1}"),
                format!("{:.1}", log.total_epochs()),
            ]);
        }
    }
    print_table(
        "Fig 7: rigid baseline vs Chicle (mSGD, K=16)",
        &["workload", "system", "best acc", "epochs→target", "ms/iter (wall)", "epochs run"],
        &rows,
    );
    let mut tsv = String::from("workload\tsystem\tbest_acc\tepochs_to_target\tms_per_iter\n");
    for r in &rows {
        tsv.push_str(&r[..5].join("\t"));
        tsv.push('\n');
    }
    write_tsv("fig7_summary.tsv", &tsv)?;
    println!("\nExpected shape (paper §A.1): identical per-epoch convergence; Chicle");
    println!("per-iteration overhead negligible vs the rigid baseline.");
    Ok(())
}
