//! Figures 5 + 10: load balancing on a heterogeneous cluster — 8 fast +
//! 8 slow (1.5×) nodes; convergence over projected time and per epoch,
//! uni-tasks (rebalance policy on) vs micro-task emulation.
//!
//! Per paper §5.4: per epoch Chicle matches micro-tasks(16); over time it
//! beats every micro-task configuration because it balances at chunk
//! granularity (iteration 1.2 time units vs 1.25 for the best micro-task
//! schedule — and micro-tasks(16) cannot balance at all: 1.5 units).

use chicle::coordinator::TrainingSession;
use chicle::harness::{
    fast_mode, heterogeneous_spec, print_table, summarize, task_model_variants, write_tsv,
    Workload,
};

fn main() -> chicle::Result<()> {
    let args: Vec<String> = std::env::args().collect();
    let which = args
        .iter()
        .position(|a| a == "--workloads")
        .and_then(|i| args.get(i + 1))
        .map(|s| s.as_str())
        .unwrap_or("all");
    let workloads: Vec<Workload> = match which {
        "cocoa" => vec![Workload::HiggsLike, Workload::CriteoLike],
        "lsgd" => vec![Workload::CifarLike, Workload::FmnistLike],
        _ => vec![
            Workload::HiggsLike,
            Workload::CriteoLike,
            Workload::FmnistLike,
            Workload::CifarLike,
        ],
    };
    let micro_ks: &[usize] = if fast_mode() { &[16, 64] } else { &[16, 24, 32, 64] };

    let mut summary = Vec::new();
    for w in &workloads {
        for (variant, tm) in task_model_variants(micro_ks) {
            let name = format!("fig5_{}_{}", w.name(), variant);
            let ds = w.dataset(42);
            let mut cfg = w.session(&name, 16);
            cfg.elastic = heterogeneous_spec();
            cfg.task_model = tm;
            cfg.policies.rebalance = true;
            cfg.max_epochs = w.horizon_epochs();
            let mut s = TrainingSession::new(cfg, ds)?;
            let log = s.run()?;
            write_tsv(&format!("{name}.tsv"), &log.to_tsv())?;
            let (epochs, time, last) = summarize(&log, w.target());
            summary.push(vec![w.name().to_string(), variant, epochs, time, last]);
        }
    }
    print_table(
        "Fig 5/10 summary: heterogeneous cluster (8 fast + 8 slow @1.5x)",
        &["workload", "tasks", "epochs", "time", "final metric"],
        &summary,
    );
    let mut tsv = String::from("workload\ttasks\tepochs_to_target\ttime_to_target\tfinal\n");
    for row in &summary {
        tsv.push_str(&row.join("\t"));
        tsv.push('\n');
    }
    write_tsv("fig5_summary.tsv", &tsv)?;
    Ok(())
}
