//! Figure 8: baseline comparison for CoCoA — "Chicle vs Snap ML"
//! (paper §5.2 / §A.1).
//!
//! Snap ML is not available offline; the rigid baseline is this stack
//! with policies disabled, MPI-style fixed K=16 and — the one behavioural
//! difference the paper reports — **contiguous partitioning**: Snap ML
//! splits the dataset into 16 contiguous blocks, Chicle assigns random
//! chunks. On Criteo(-like) data, whose consecutive samples are
//! session-correlated, contiguous partitioning concentrates correlated
//! samples on single workers and convergence suffers; on HIGGS(-like)
//! i.i.d. data the two coincide (paper: "Chicle performed virtually
//! identically for the Higgs dataset but outperformed it for Criteo").

use chicle::config::Partitioning;
use chicle::coordinator::TrainingSession;
use chicle::harness::{fast_mode, print_table, rigid_policies, summarize, write_tsv, Workload};

fn main() -> chicle::Result<()> {
    let workloads = [Workload::HiggsLike, Workload::CriteoLike];
    let mut rows = Vec::new();
    for w in &workloads {
        for (label, partitioning) in [
            ("snapml-rigid (contiguous)", Partitioning::Contiguous),
            ("chicle (random chunks)", Partitioning::RandomChunks),
        ] {
            let name = format!(
                "fig8_{}_{}",
                w.name(),
                if matches!(partitioning, Partitioning::Contiguous) { "contig" } else { "random" }
            );
            let ds = w.dataset(42);
            let mut cfg = w.session(&name, 16);
            cfg.partitioning = partitioning;
            if matches!(partitioning, Partitioning::Contiguous) {
                cfg.policies = rigid_policies();
            }
            cfg.max_iters = if fast_mode() { 15 } else { 80 };
            let mut s = TrainingSession::new(cfg, ds)?;
            let log = s.run()?;
            write_tsv(&format!("{name}.tsv"), &log.to_tsv())?;
            let (epochs, time, last) = summarize(&log, w.target());
            rows.push(vec![w.name().to_string(), label.to_string(), epochs, time, last]);
        }
    }
    print_table(
        "Fig 8: Snap-ML-style rigid baseline vs Chicle (CoCoA, K=16)",
        &["workload", "system", "epochs→target", "time→target", "final gap"],
        &rows,
    );
    let mut tsv = String::from("workload\tsystem\tepochs_to_target\ttime_to_target\tfinal\n");
    for r in &rows {
        tsv.push_str(&r.join("\t"));
        tsv.push('\n');
    }
    write_tsv("fig8_summary.tsv", &tsv)?;
    println!("\nExpected shape (paper §A.1): ~identical on higgs_like; Chicle converges");
    println!("in fewer epochs on criteo_like due to partitioning sensitivity.");
    Ok(())
}
