//! Figures 6 + 11: the load-balancing process visualized as swimlanes —
//! per-task runtimes per iteration without and with the rebalance policy,
//! plus the relative per-task workload (chunk counts).
//!
//! Cluster: 16 nodes of which 4 are down-clocked to 1.2 GHz (speed
//! 1.2/2.6 ≈ 0.46), matching the paper's §5.4 second scenario. Without
//! load balancing, iteration time is pinned to the slow nodes; with it,
//! chunks drain from slow to fast nodes over the first few iterations
//! until runtimes align.

use chicle::config::ElasticSpec;
use chicle::coordinator::TrainingSession;
use chicle::harness::{print_table, write_tsv, Workload};

fn run(workload: &Workload, rebalance: bool, iters: usize) -> chicle::Result<TrainingSession> {
    let name = format!(
        "fig6_{}_{}",
        workload.name(),
        if rebalance { "lb" } else { "nolb" }
    );
    let ds = workload.dataset(42);
    let mut cfg = workload.session(&name, 16);
    // 12 fast nodes + 4 down-clocked to 1.2/2.6 GHz.
    let mut speeds = vec![1.0; 12];
    speeds.extend(vec![1.2 / 2.6; 4]);
    cfg.elastic = ElasticSpec::Trace { points: vec![(0.0, speeds)] };
    cfg.policies.rebalance = rebalance;
    cfg.policies.rebalance_step = 4;
    cfg.max_iters = iters;
    cfg.max_epochs = f64::INFINITY;
    let mut s = TrainingSession::new(cfg, ds)?;
    s.run_iters(iters)?;
    Ok(s)
}

fn main() -> chicle::Result<()> {
    let args: Vec<String> = std::env::args().collect();
    let which = args
        .iter()
        .position(|a| a == "--workload")
        .and_then(|i| args.get(i + 1))
        .map(|s| s.as_str())
        .unwrap_or("criteo");
    let (workload, iters) = match which {
        "higgs" => (Workload::HiggsLike, 10),
        "fmnist" => (Workload::FmnistLike, 50),
        _ => (Workload::CriteoLike, 10),
    };

    println!("running WITHOUT load balancing ({} iterations)...", iters);
    let no_lb = run(&workload, false, iters)?;
    println!("running WITH load balancing...");
    let lb = run(&workload, true, iters)?;

    println!("\n-- task runtimes per node, NO load balancing (Fig 6 top) --");
    print!("{}", no_lb.swimlanes().render_ascii(100));
    println!("\n-- task runtimes per node, WITH load balancing (Fig 6 middle) --");
    print!("{}", lb.swimlanes().render_ascii(100));
    println!("\n-- relative workload (chunks/task, final iteration; Fig 6 bottom) --");
    print!("{}", lb.swimlanes().render_workload());

    let mut rows = Vec::new();
    for it in 0..iters {
        let d0 = no_lb
            .swimlanes()
            .iteration_duration(it)
            .map_or(0.0, |d| d.as_secs_f64());
        let d1 = lb
            .swimlanes()
            .iteration_duration(it)
            .map_or(0.0, |d| d.as_secs_f64());
        let i0 = no_lb.swimlanes().imbalance(it).unwrap_or(0.0);
        let i1 = lb.swimlanes().imbalance(it).unwrap_or(0.0);
        rows.push(vec![
            format!("{it}"),
            format!("{d0:.3}"),
            format!("{d1:.3}"),
            format!("{i0:.2}"),
            format!("{i1:.2}"),
        ]);
    }
    print_table(
        &format!("iteration durations & imbalance ({})", workload.name()),
        &["iter", "dur no-LB", "dur LB", "imbalance no-LB", "imbalance LB"],
        &rows,
    );

    write_tsv(
        &format!("fig6_{}_nolb_spans.tsv", workload.name()),
        &no_lb.swimlanes().to_tsv(),
    )?;
    write_tsv(
        &format!("fig6_{}_lb_spans.tsv", workload.name()),
        &lb.swimlanes().to_tsv(),
    )?;

    let last = iters - 1;
    let (i_no, i_lb) = (
        no_lb.swimlanes().imbalance(last).unwrap_or(1.0),
        lb.swimlanes().imbalance(last).unwrap_or(1.0),
    );
    println!("\nfinal-iteration imbalance: {i_no:.2}x (no LB) -> {i_lb:.2}x (LB)");
    Ok(())
}
