//! Quickstart: train an SVM with CoCoA on 4 simulated nodes through the
//! full production path — JAX/Pallas AOT artifacts executed via PJRT
//! from the rust coordinator. Falls back to the native backend when
//! artifacts are missing (`make artifacts` builds them).
//!
//!     cargo run --release --example quickstart

use chicle::config::{ComputeBackend, SessionConfig};
use chicle::coordinator::TrainingSession;
use chicle::data::synth;

fn main() -> chicle::Result<()> {
    let dataset = synth::higgs_like(8_000, 42);
    println!(
        "dataset: {} ({} samples, {} features)",
        dataset.name,
        dataset.n_samples(),
        dataset.dim()
    );

    let mut cfg = SessionConfig::cocoa("quickstart", 4);
    cfg.chunk_bytes = 16 * 1024;
    cfg.max_iters = 30;
    cfg.backend = if std::path::Path::new("artifacts/manifest.json").exists() {
        println!("backend: HLO via PJRT (AOT JAX/Pallas artifacts)");
        ComputeBackend::Hlo
    } else {
        println!("backend: native (run `make artifacts` for the HLO path)");
        ComputeBackend::Native
    };

    let mut session = TrainingSession::new(cfg, dataset)?;
    let log = session.run()?;

    println!("\niter  epochs  gap");
    for r in log.records.iter().step_by(3) {
        if let Some(m) = r.metric {
            println!("{:>4}  {:>6.1}  {:.6}", r.iter, r.epochs, m.value());
        }
    }
    let gap = log.last_gap().expect("gap recorded");
    println!(
        "\nconverged to duality gap {gap:.6} in {} iterations ({:.2}s wall)",
        log.records.len(),
        log.total_wall().as_secs_f64()
    );
    assert!(gap < 0.01, "quickstart should converge");
    Ok(())
}
