//! Table 1: datasets used in the evaluation — number of samples (#S),
//! features (#F), categories (#C) and in-memory size.
//!
//! The paper's corpora (HIGGS, Criteo, CIFAR-10, Fashion-MNIST) are
//! substituted with synthetic equivalents at a scale this testbed trains
//! in minutes; see DESIGN.md §Substitutions. Paper values are printed
//! alongside for reference.

use chicle::harness::{print_table, write_tsv, Workload};

fn human(bytes: usize) -> String {
    if bytes >= 1 << 30 {
        format!("{:.1}GiB", bytes as f64 / (1u64 << 30) as f64)
    } else if bytes >= 1 << 20 {
        format!("{:.1}MiB", bytes as f64 / (1 << 20) as f64)
    } else {
        format!("{:.1}KiB", bytes as f64 / (1 << 10) as f64)
    }
}

fn main() -> chicle::Result<()> {
    let paper: &[(&str, &str, &str, &str, &str)] = &[
        ("HIGGS", "11M", "28", "2", "2.5GiB"),
        ("Criteo", "46M", "1M", "2", "15GiB"),
        ("CIFAR-10", "60k", "3072", "10", "162MiB"),
        ("Fashion-MNIST", "70k", "784", "10", "30MiB"),
    ];
    let workloads = [
        Workload::HiggsLike,
        Workload::CriteoLike,
        Workload::CifarLike,
        Workload::FmnistLike,
    ];
    let mut rows = Vec::new();
    let mut tsv = String::from("dataset\tsamples\tfeatures\tclasses\tsize_bytes\n");
    for (w, p) in workloads.iter().zip(paper) {
        let ds = w.dataset(42);
        let classes = match &ds.labels {
            chicle::data::Labels::Binary(_) => 2,
            chicle::data::Labels::Class(_) => ds.n_classes(),
            chicle::data::Labels::None => 0,
        };
        rows.push(vec![
            ds.name.clone(),
            format!("{}", ds.n_samples()),
            format!("{}", ds.dim()),
            format!("{classes}"),
            human(ds.size_bytes()),
            format!("(paper {}: {} / {} / {} / {})", p.0, p.1, p.2, p.3, p.4),
        ]);
        tsv.push_str(&format!(
            "{}\t{}\t{}\t{}\t{}\n",
            ds.name,
            ds.n_samples(),
            ds.dim(),
            classes,
            ds.size_bytes()
        ));
    }
    print_table(
        "Table 1: evaluation datasets (synthetic equivalents)",
        &["dataset", "#S", "#F", "#C", "size", "paper reference"],
        &rows,
    );
    write_tsv("table1_datasets.tsv", &tsv)?;
    Ok(())
}
