//! Figures 4 + 9: elastic scale-in (16→2) and scale-out (2→16), ±2 nodes
//! every 20 s — convergence over (projected) time and per epoch, uni-tasks
//! vs micro-task emulation with K ∈ {16, 24, 32, 64}.
//!
//! Per paper §5.3: convergence is measured with real training; time is
//! projected with the wave/balance model (transfer overheads excluded,
//! which favors micro-tasks). One TSV per (workload, scenario, variant)
//! lands in results/, plus a summary table of epochs/time to target.
//!
//! `CHICLE_FAST=1` runs a reduced matrix. `--workloads cocoa|lsgd|all`
//! selects the workload family (default: all; the CNN runs dominate
//! wall-clock).

use chicle::config::SessionConfig;
use chicle::coordinator::TrainingSession;
use chicle::harness::{
    fast_mode, print_table, scale_in_spec, scale_out_spec, summarize, task_model_variants,
    write_tsv, Workload,
};

fn run_matrix(workloads: &[Workload]) -> chicle::Result<()> {
    let micro_ks: &[usize] = if fast_mode() { &[16, 64] } else { &[16, 24, 32, 64] };
    let scenarios: &[(&str, fn() -> chicle::config::ElasticSpec)] =
        &[("scale_in", scale_in_spec), ("scale_out", scale_out_spec)];

    let mut summary = Vec::new();
    for w in workloads {
        for (scen_name, scen) in scenarios {
            for (variant, tm) in task_model_variants(micro_ks) {
                let name = format!("fig4_{}_{}_{}", w.name(), scen_name, variant);
                let ds = w.dataset(42);
                let mut cfg: SessionConfig = w.session(&name, 16);
                cfg.elastic = scen();
                cfg.task_model = tm;
                // Run a fixed horizon so the full curve is recorded.
                cfg.max_epochs = w.horizon_epochs();
                let mut s = TrainingSession::new(cfg, ds)?;
                let log = s.run()?;
                write_tsv(&format!("{name}.tsv"), &log.to_tsv())?;
                let (epochs, time, last) = summarize(&log, w.target());
                summary.push(vec![
                    w.name().to_string(),
                    scen_name.to_string(),
                    variant.clone(),
                    epochs,
                    time,
                    last,
                ]);
            }
        }
    }
    print_table(
        "Fig 4/9 summary: epochs & projected time to target",
        &["workload", "scenario", "tasks", "epochs", "time", "final metric"],
        &summary,
    );
    let mut tsv =
        String::from("workload\tscenario\ttasks\tepochs_to_target\ttime_to_target\tfinal\n");
    for row in &summary {
        tsv.push_str(&row.join("\t"));
        tsv.push('\n');
    }
    write_tsv("fig4_summary.tsv", &tsv)?;
    Ok(())
}

fn main() -> chicle::Result<()> {
    let args: Vec<String> = std::env::args().collect();
    let which = args
        .iter()
        .position(|a| a == "--workloads")
        .and_then(|i| args.get(i + 1))
        .map(|s| s.as_str())
        .unwrap_or("all");
    let workloads: Vec<Workload> = match which {
        "cocoa" => vec![Workload::HiggsLike, Workload::CriteoLike],
        "lsgd" => vec![Workload::CifarLike, Workload::FmnistLike],
        _ => vec![
            Workload::HiggsLike,
            Workload::CriteoLike,
            Workload::FmnistLike,
            Workload::CifarLike,
        ],
    };
    run_matrix(&workloads)
}
