//! End-to-end validation (DESIGN.md §6): train a decoder-only transformer
//! LM with local SGD through the **full** Chicle stack — synthetic token
//! corpus chunked into mobile chunks, elastic trace enabled, compute via
//! AOT JAX/Pallas artifacts on PJRT (Python never on the training path).
//!
//! The default `tfm_small` preset (~0.5M params) trains a few hundred
//! steps in minutes on this CPU testbed; `make artifacts` with
//! `--tfm-preset e2e` (~8M) or `100m` scales up the same artifact flow.
//!
//!     cargo run --release --example train_transformer [--iters N]

use chicle::config::{AlgoConfig, ComputeBackend, ElasticSpec, ModelKind, SessionConfig};
use chicle::coordinator::TrainingSession;
use chicle::data::synth;
use chicle::harness::write_tsv;

fn main() -> chicle::Result<()> {
    let args: Vec<String> = std::env::args().collect();
    let iters: usize = args
        .iter()
        .position(|a| a == "--iters")
        .and_then(|i| args.get(i + 1))
        .and_then(|s| s.parse().ok())
        .unwrap_or(100);

    if !std::path::Path::new("artifacts/manifest.json").exists() {
        anyhow::bail!("run `make artifacts` first (LM training needs the HLO backend)");
    }

    // Markov-chain token corpus: 512 sequences × 64 tokens, vocab 1024.
    let corpus = synth::token_corpus(512, 64, 1024, 42);
    println!(
        "corpus: {} sequences × 64 tokens (vocab 1024), {} KiB",
        corpus.n_samples(),
        corpus.size_bytes() / 1024
    );

    let mut cfg = SessionConfig::lsgd("train-transformer", ModelKind::TfmSmall, 2);
    cfg.backend = ComputeBackend::Hlo;
    cfg.chunk_bytes = 16 * 1024;
    // Elastic: start on 2 nodes, scale to 4 mid-training (lSGD iterations
    // are 1 projected time unit each, so +2 nodes every 10 iterations).
    cfg.elastic = ElasticSpec::Gradual { from: 2, to: 4, interval_s: 10.0 };
    cfg.test_frac = 0.1;
    cfg.max_iters = iters;
    if let AlgoConfig::Lsgd(l) = &mut cfg.algo {
        l.l = 8; // must match the AOT grad artifact batch
        l.h = 4;
        l.lr = 5e-3;
        l.momentum = 0.9;
        l.scale_lr = true;
        l.eval_every = 5;
        l.target_acc = 0.0; // EvalLoss metric: 0.0 is unreachable → full run
    }

    let mut session = TrainingSession::new(cfg, corpus)?;
    println!("training {iters} iterations (H=4 local steps × L=8 seqs per task)...\n");
    println!("iter  nodes  epochs  train-loss  eval-loss");
    let log = session.run_iters(iters)?;
    for r in &log.records {
        println!(
            "{:>4}  {:>5}  {:>6.2}  {:>10}  {}",
            r.iter,
            r.n_tasks,
            r.epochs,
            r.train_loss.map_or("—".into(), |l| format!("{l:.4}")),
            r.metric.map_or("—".into(), |m| format!("{:.4}", m.value())),
        );
    }
    write_tsv("train_transformer_loss.tsv", &log.to_tsv())?;

    let first_loss = log
        .records
        .iter()
        .find_map(|r| r.train_loss)
        .expect("train loss recorded");
    let last_loss = log
        .records
        .iter()
        .rev()
        .find_map(|r| r.train_loss)
        .unwrap();
    println!(
        "\ntrain loss {first_loss:.4} -> {last_loss:.4} over {} iterations ({:.1}s wall)",
        log.records.len(),
        log.total_wall().as_secs_f64()
    );
    anyhow::ensure!(
        last_loss < first_loss,
        "loss should decrease ({first_loss} -> {last_loss})"
    );
    println!("end-to-end OK: rust coordinator × PJRT × Pallas-lowered HLO, elastic 2→4 nodes");
    Ok(())
}
