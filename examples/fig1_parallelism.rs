//! Figure 1: the correlation between data parallelism and the number of
//! epochs needed to reach a training goal.
//!
//! * (a) mSGD/CNN on cifar_like: sweep the global batch size (K·L with
//!   H = 1, the mSGD special case) and measure epochs to the target test
//!   accuracy — the paper reports e.g. +44% epochs from batch 256 → 512.
//! * (b) CoCoA/SVM on criteo_like: sweep the number of partitions K and
//!   measure epochs to the target duality gap — the paper reports +65%
//!   from 16 → 32 partitions.
//!
//! Run `--part a`, `--part b`, or both (default). `CHICLE_FAST=1` shrinks
//! the sweep.

use chicle::config::{AlgoConfig, SessionConfig, TaskModel};
use chicle::coordinator::TrainingSession;
use chicle::harness::{fast_mode, print_table, summarize, write_tsv, Workload};

fn part_a() -> chicle::Result<()> {
    println!("Fig 1a: epochs to {:.0}% accuracy vs global batch (mSGD/CNN, cifar_like)",
             Workload::CifarLike.target() * 100.0);
    let ks: &[usize] = if fast_mode() { &[2, 8] } else { &[2, 4, 8, 16, 32] };
    let mut rows = Vec::new();
    let mut tsv = String::from("batch\tk\tepochs_to_target\tbest_acc\n");
    for &k in ks {
        let ds = Workload::CifarLike.dataset(42);
        let mut cfg = Workload::CifarLike.session(&format!("fig1a-k{k}"), k);
        cfg.task_model = TaskModel::MicroTasks { k };
        if let AlgoConfig::Lsgd(l) = &mut cfg.algo {
            l.h = 1; // mSGD
            l.eval_every = 5;
        }
        cfg.max_iters = if fast_mode() { 100 } else { 4000 };
        cfg.max_epochs = 20.0;
        let batch = k * 8;
        let mut s = TrainingSession::new(cfg, ds)?;
        let log = s.run()?;
        let (epochs, _, last) = summarize(&log, Workload::CifarLike.target());
        let best = log.best_accuracy().unwrap_or(0.0);
        rows.push(vec![
            format!("{batch}"),
            format!("{k}"),
            epochs.clone(),
            format!("{best:.3}"),
        ]);
        tsv.push_str(&format!("{batch}\t{k}\t{epochs}\t{best:.4}\n"));
        let _ = last;
    }
    print_table(
        "Fig 1a (epochs to target vs batch size)",
        &["batch (K·L)", "K", "epochs", "best acc"],
        &rows,
    );
    write_tsv("fig1a_batch_vs_epochs.tsv", &tsv)?;
    Ok(())
}

fn part_b() -> chicle::Result<()> {
    println!("Fig 1b: epochs to gap {:.0e} vs #partitions (CoCoA/SVM, criteo_like)",
             Workload::CriteoLike.target());
    let ks: &[usize] = if fast_mode() { &[2, 16] } else { &[2, 4, 8, 16, 32, 64] };
    let mut rows = Vec::new();
    let mut tsv = String::from("k\tepochs_to_target\tfinal_gap\n");
    for &k in ks {
        let ds = Workload::CriteoLike.dataset(42);
        let mut cfg = Workload::CriteoLike.session(&format!("fig1b-k{k}"), k);
        cfg.task_model = TaskModel::MicroTasks { k };
        cfg.max_iters = if fast_mode() { 20 } else { 120 };
        let mut s = TrainingSession::new(cfg, ds)?;
        let log = s.run()?;
        let (epochs, _, last) = summarize(&log, Workload::CriteoLike.target());
        rows.push(vec![format!("{k}"), epochs.clone(), last.clone()]);
        tsv.push_str(&format!("{k}\t{epochs}\t{last}\n"));
    }
    print_table(
        "Fig 1b (epochs to target vs #partitions)",
        &["K", "epochs", "final gap"],
        &rows,
    );
    write_tsv("fig1b_partitions_vs_epochs.tsv", &tsv)?;
    Ok(())
}

fn main() -> chicle::Result<()> {
    let args: Vec<String> = std::env::args().collect();
    let part = args
        .iter()
        .position(|a| a == "--part")
        .and_then(|i| args.get(i + 1))
        .map(|s| s.as_str())
        .unwrap_or("both");
    if part == "a" || part == "both" {
        part_a()?;
    }
    if part == "b" || part == "both" {
        part_b()?;
    }
    Ok(())
}
